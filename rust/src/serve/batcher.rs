//! The micro-batcher: drains concurrent `/eval` requests into the
//! fixed-shape work-queue evaluator so the `apply_b{B}` batch stays full.
//!
//! Connection handlers park [`EvalWork`] items on a bounded
//! [`BatchQueue`]; the single batcher thread drains everything queued,
//! groups it FIFO by policy ([`plan_batches`]), and runs each group as
//! one `run_episode_queue` pass — episodes from unrelated requests share
//! batch columns. Because every episode's RNG stream is content-keyed
//! ([`adhoc_episode_rng`]: a function of (master, level bytes, trial)
//! only), sharing a batch cannot change any level's result: batched
//! output is bit-identical to the solo
//! [`evaluate_levels`](crate::eval::evaluate_levels) reference path.
//!
//! Ordering is FIFO-deterministic end to end: the queue preserves arrival
//! order, `plan_batches` groups by first appearance, and episodes are
//! flattened work-by-work, level-by-level, trial-by-trial. No step
//! consults a hash map (`serve/` is lint-scoped order-sensitive), so the
//! batch assembly for a given arrival order is reproducible — and thanks
//! to the content-keyed streams, even a *different* arrival order changes
//! only scheduling, never results.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{mpsc, Condvar, Mutex};

use crate::env::{LevelMeta, UnderspecifiedEnv};
use crate::eval::{adhoc_episode_rng, LevelResult};
use crate::metrics::ServeMetrics;
use crate::rollout::RolloutEngine;

use super::cache::{cache_key, ResultCache};
use super::zoo::{DynPolicy, PolicyStore};

/// One level awaiting evaluation: its position in the originating
/// request, its canonical bytes (the RNG/cache key), and the decoded
/// level.
pub struct PendingLevel<L> {
    pub idx: usize,
    pub bytes: Vec<u8>,
    pub level: L,
}

/// One `/eval` request's cache-miss remainder, queued for the batcher.
pub struct EvalWork<L> {
    pub policy: String,
    pub trials: usize,
    pub master: u64,
    pub levels: Vec<PendingLevel<L>>,
    /// Where the batcher delivers this request's results.
    pub respond: mpsc::Sender<BatchOutcome>,
}

/// What the batcher sends back per request.
pub struct BatchOutcome {
    /// `(request level index, result)` pairs, request order.
    pub results: Vec<(usize, LevelResult)>,
    /// Forward passes of the engine run that computed these results.
    /// Shared across every request in the same policy group — the whole
    /// point of micro-batching is that one pass serves many requests.
    pub forward_passes: u64,
    /// Set when the group failed (policy load or engine error); the
    /// router maps it to a 500.
    pub error: Option<String>,
}

struct QueueInner<L> {
    works: VecDeque<EvalWork<L>>,
    shutdown: bool,
}

/// Bounded MPSC hand-off between connection handlers and the batcher.
pub struct BatchQueue<L> {
    inner: Mutex<QueueInner<L>>,
    cv: Condvar,
    cap: usize,
}

impl<L> BatchQueue<L> {
    pub fn new(cap: usize) -> BatchQueue<L> {
        BatchQueue {
            inner: Mutex::new(QueueInner { works: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue; `false` means the queue is full (shed with 503) or the
    /// server is shutting down.
    pub fn push(&self, work: EvalWork<L>) -> bool {
        // ued-lint: allow(serve-panic) — poisoned queue mutex means a batcher thread already panicked; propagating is crash-consistent
        let mut inner = self.inner.lock().expect("batch queue poisoned");
        if inner.shutdown || inner.works.len() >= self.cap {
            return false;
        }
        inner.works.push_back(work);
        self.cv.notify_one();
        true
    }

    /// Block until work arrives, then drain *everything* queued (the
    /// batcher wants the widest batch available). Returns `None` only
    /// once shut down *and* empty, so in-flight requests still complete
    /// during shutdown.
    // ued-lint: allow(serve-panic) — both expects fire only on a poisoned mutex, i.e. after another thread's panic
    pub fn drain_blocking(&self) -> Option<Vec<EvalWork<L>>> {
        let mut inner = self.inner.lock().expect("batch queue poisoned");
        loop {
            if !inner.works.is_empty() {
                return Some(inner.works.drain(..).collect());
            }
            if inner.shutdown {
                return None;
            }
            inner = self.cv.wait(inner).expect("batch queue poisoned");
        }
    }

    pub fn shutdown(&self) {
        // ued-lint: allow(serve-panic) — poisoned-mutex expect; see push
        self.inner.lock().expect("batch queue poisoned").shutdown = true;
        self.cv.notify_all();
    }

    /// Currently queued works (metrics).
    pub fn depth(&self) -> usize {
        // ued-lint: allow(serve-panic) — poisoned-mutex expect; see push
        self.inner.lock().expect("batch queue poisoned").works.len()
    }
}

/// Group a drained batch by policy, preserving FIFO order: groups appear
/// in order of each policy's first appearance, and indices within a group
/// keep arrival order. Pure and hash-free, so the plan for a given
/// arrival order is always the same — the pinned-ordering contract the
/// lint fixture (`tests/lint_fixtures/serve_batcher.rs`) documents.
pub fn plan_batches<L>(works: &[EvalWork<L>]) -> Vec<(String, Vec<usize>)> {
    let mut plan: Vec<(String, Vec<usize>)> = Vec::new();
    for (i, w) in works.iter().enumerate() {
        match plan.iter_mut().find(|(p, _)| *p == w.policy) {
            Some((_, idxs)) => idxs.push(i),
            None => plan.push((w.policy.clone(), vec![i])),
        }
    }
    plan
}

/// Run one drained batch: one engine pass per policy group, results
/// cached and delivered per request. Send failures are ignored — a
/// client that hung up simply doesn't collect its results.
// ued-lint: allow(serve-panic) — every index below reads `works`/`slots`/`ep_map` through indices minted from those same vectors a few lines up; in-bounds by construction
pub fn run_batches<E: UnderspecifiedEnv>(
    env: &E, engine: &mut RolloutEngine, store: &mut PolicyStore, cache: &ResultCache,
    metrics: &ServeMetrics, max_steps: usize, works: Vec<EvalWork<E::Level>>,
) {
    for (policy_id, work_idxs) in plan_batches(&works) {
        // Flatten FIFO: work-by-work, level-by-level, trial-by-trial.
        // `slots[s]` is the s-th (work, level) pair; episode e maps to
        // (slot, trial) via `ep_map`, keeping each slot's trials in one
        // contiguous outcome run.
        let mut slots: Vec<(usize, usize)> = Vec::new();
        let mut ep_map: Vec<(usize, usize)> = Vec::new();
        for &wi in &work_idxs {
            let w = &works[wi];
            for li in 0..w.levels.len() {
                let s = slots.len();
                slots.push((wi, li));
                for t in 0..w.trials {
                    ep_map.push((s, t));
                }
            }
        }
        let n = ep_map.len();
        if n == 0 {
            for &wi in &work_idxs {
                let _ = works[wi].respond.send(BatchOutcome {
                    results: Vec::new(),
                    forward_passes: 0,
                    error: None,
                });
            }
            continue;
        }

        let run = store.with_model(&policy_id, |model| {
            let policy = DynPolicy(model);
            engine.run_episode_queue(env, &policy, n, max_steps, false, |e| {
                let (s, trial) = ep_map[e];
                let (wi, li) = slots[s];
                let w = &works[wi];
                let pl = &w.levels[li];
                let mut r = adhoc_episode_rng(w.master, &pl.bytes, trial);
                let state = env.reset_to_level(&pl.level, &mut r);
                (state, r)
            })
        });

        match run {
            Ok(outcomes) => {
                let forward_passes = engine.forward_passes();
                metrics.forward_passes.fetch_add(forward_passes, Relaxed);
                metrics.batches.fetch_add(1, Relaxed);
                metrics.batched_episodes.fetch_add(n as u64, Relaxed);
                metrics.add_phase_timers(&engine.take_timers());

                let mut per_work: BTreeMap<usize, Vec<(usize, LevelResult)>> =
                    BTreeMap::new();
                let mut off = 0usize;
                for &(wi, li) in &slots {
                    let w = &works[wi];
                    let outs = &outcomes[off..off + w.trials];
                    off += w.trials;
                    let pl = &w.levels[li];
                    // Content-derived name: stable across requests, so a
                    // cached result carries the same name a fresh one would.
                    let name = format!("{:016x}", pl.level.fingerprint());
                    let lr = LevelResult::from_outcomes(name, outs);
                    cache.insert(
                        cache_key(&w.policy, w.trials, w.master, &pl.bytes),
                        lr.clone(),
                    );
                    per_work.entry(wi).or_default().push((pl.idx, lr));
                }
                for &wi in &work_idxs {
                    let _ = works[wi].respond.send(BatchOutcome {
                        results: per_work.remove(&wi).unwrap_or_default(),
                        forward_passes,
                        error: None,
                    });
                }
            }
            Err(e) => {
                for &wi in &work_idxs {
                    let _ = works[wi].respond.send(BatchOutcome {
                        results: Vec::new(),
                        forward_passes: 0,
                        error: Some(format!("{e:#}")),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::env::maze::MazeEnv;
    use crate::env::{holdout, UnderspecifiedEnv};
    use crate::eval::evaluate_levels;
    use crate::rollout::WorkerPool;
    use crate::serve::zoo::{ZooCatalog, ZooSource};

    fn work(policy: &str) -> (EvalWork<crate::env::level::Level>, mpsc::Receiver<BatchOutcome>) {
        let (tx, rx) = mpsc::channel();
        (
            EvalWork {
                policy: policy.to_string(),
                trials: 1,
                master: 0,
                levels: Vec::new(),
                respond: tx,
            },
            rx,
        )
    }

    #[test]
    fn plan_is_fifo_by_first_appearance() {
        // policies [b, a, b, c] → groups [(b, [0, 2]), (a, [1]), (c, [3])]
        let (w0, _r0) = work("b");
        let (w1, _r1) = work("a");
        let (w2, _r2) = work("b");
        let (w3, _r3) = work("c");
        let plan = plan_batches(&[w0, w1, w2, w3]);
        assert_eq!(
            plan,
            vec![
                ("b".to_string(), vec![0, 2]),
                ("a".to_string(), vec![1]),
                ("c".to_string(), vec![3]),
            ]
        );
    }

    #[test]
    fn queue_sheds_when_full_and_drains_fifo() {
        let q: BatchQueue<crate::env::level::Level> = BatchQueue::new(2);
        let (w0, _r0) = work("a");
        let (w1, _r1) = work("b");
        let (w2, _r2) = work("c");
        assert!(q.push(w0));
        assert!(q.push(w1));
        assert!(!q.push(w2), "over cap must shed");
        assert_eq!(q.depth(), 2);
        let drained = q.drain_blocking().unwrap();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].policy, "a");
        assert_eq!(drained[1].policy, "b");
        assert_eq!(q.depth(), 0);
        q.shutdown();
        assert!(q.drain_blocking().is_none(), "shutdown + empty ends the loop");
        let (w3, _r3) = work("d");
        assert!(!q.push(w3), "no new work after shutdown");
    }

    #[test]
    fn batched_results_match_the_solo_reference_bit_for_bit() {
        let env = MazeEnv::new(40);
        let b = 4;
        let trials = 3;
        let master = 7u64;
        let named: Vec<(String, crate::env::level::Level)> = holdout::named_levels()
            .into_iter()
            .take(4)
            .map(|n| (n.name.to_string(), n.level))
            .collect();

        // Solo reference: each half of the level list evaluated alone.
        let pool = Arc::new(WorkerPool::new(1));
        let policy =
            crate::rollout::SyntheticPolicy { num_actions: env.num_actions() };
        let solo_a = evaluate_levels(
            &env, &policy, &named[..2], trials, 40, b, master, pool.clone(),
        )
        .unwrap();
        let solo_b = evaluate_levels(
            &env, &policy, &named[2..], trials, 40, b, master, pool.clone(),
        )
        .unwrap();

        // Batched: the same halves as two concurrent works in one drain.
        let catalog = Arc::new(ZooCatalog::new(vec![(
            "synthetic0".to_string(),
            ZooSource::Synthetic { num_actions: env.num_actions() },
        )]));
        let mut store =
            PolicyStore::new(None, None, "student_apply_b4".into(), 4, 2, catalog);
        let cache = ResultCache::new(64);
        let metrics = ServeMetrics::default();
        let mut engine = RolloutEngine::with_pool(&env, b, pool);
        let make_work = |levels: &[(String, crate::env::level::Level)]| {
            let (tx, rx) = mpsc::channel();
            (
                EvalWork {
                    policy: "synthetic0".to_string(),
                    trials,
                    master,
                    levels: levels
                        .iter()
                        .enumerate()
                        .map(|(i, (_, l))| PendingLevel {
                            idx: i,
                            bytes: l.encode(),
                            level: l.clone(),
                        })
                        .collect(),
                    respond: tx,
                },
                rx,
            )
        };
        let (wa, ra) = make_work(&named[..2]);
        let (wb, rb) = make_work(&named[2..]);
        run_batches(&env, &mut engine, &mut store, &cache, &metrics, 40, vec![wa, wb]);

        let out_a = ra.recv().unwrap();
        let out_b = rb.recv().unwrap();
        assert!(out_a.error.is_none() && out_b.error.is_none());
        for (solo, out, levels) in
            [(&solo_a, &out_a, &named[..2]), (&solo_b, &out_b, &named[2..])]
        {
            assert_eq!(out.results.len(), levels.len());
            for (i, (_, level)) in levels.iter().enumerate() {
                let (idx, got) = &out.results[i];
                assert_eq!(*idx, i);
                let want = &solo.levels[i];
                assert_eq!(
                    got.solve_rate.to_bits(),
                    want.solve_rate.to_bits(),
                    "level {i}: batched vs solo solve rate"
                );
                assert_eq!(got.mean_steps.to_bits(), want.mean_steps.to_bits());
                // and the cache now holds the same bits
                let cached = cache
                    .get(&cache_key("synthetic0", trials, master, &level.encode()))
                    .expect("computed result must be cached");
                assert_eq!(cached.solve_rate.to_bits(), got.solve_rate.to_bits());
            }
        }
        // one policy → one batched engine pass over both works
        assert_eq!(metrics.batches.load(Relaxed), 1);
        assert_eq!(metrics.batched_episodes.load(Relaxed), (4 * trials) as u64);
        assert!(metrics.forward_passes.load(Relaxed) > 0);
        assert_eq!(out_a.forward_passes, out_b.forward_passes);
    }
}
