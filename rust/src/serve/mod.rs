//! `ued-serve` — a batched policy-zoo evaluation server.
//!
//! A long-running, dependency-free HTTP/1.1 + JSON server that exposes
//! the fixed-shape work-queue evaluator as a service:
//!
//! * **Zoo** — trained checkpoints discovered under `--zoo-dir` at
//!   startup (plus `--synthetic-zoo N` runtime-free policies), loaded
//!   lazily on first request and LRU-bounded at `--zoo-cap` resident.
//! * **Micro-batching** — connection handlers validate, probe the cache,
//!   and enqueue; one batcher thread drains all in-flight requests per
//!   cycle and packs their episodes into `run_episode_queue` columns so
//!   the `apply_b{B}` batch stays full across requests.
//! * **Caching** — per-`(policy, trials, seed, level-bytes)` results.
//!   The content-keyed episode RNG makes a level's result independent of
//!   its batch position, so cached replies are bit-identical to
//!   re-evaluation and cost zero forward passes.
//!
//! ```text
//!                    ┌────────────────────────────────────────────┐
//!  TCP clients ──►── │ accept loop ──► per-conn threads           │
//!                    │   http::read_request → router::handle      │
//!                    │     ├─ cache hit ──────────────► respond   │
//!                    │     └─ miss → EvalWork ─┐                  │
//!                    │                         ▼                  │
//!                    │            BatchQueue (bounded, FIFO)      │
//!                    │                         │ drain_blocking   │
//!                    │                         ▼                  │
//!                    │   batcher thread: plan_batches by policy   │
//!                    │     PolicyStore (lazy zoo, LRU)            │
//!                    │     RolloutEngine::run_episode_queue       │
//!                    │     results → cache → mpsc reply per work  │
//!                    └────────────────────────────────────────────┘
//! ```
//!
//! Endpoints: `GET /healthz`, `GET /zoo`, `GET /metrics`,
//! `POST /eval`, `POST /levels/generate` (see [`router`]).

pub mod batcher;
pub mod cache;
pub mod http;
pub mod router;
pub mod zoo;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::env::EnvFamily;
use crate::metrics::ServeMetrics;
use crate::rollout::{RolloutEngine, WorkerPool};
use crate::runtime::{discover_checkpoints, Runtime};

use batcher::BatchQueue;
use cache::ResultCache;
use router::ServeContext;
use zoo::{PolicyStore, ZooCatalog, ZooSource};

/// A running server: bound address plus handles for observation and
/// shutdown. Dropping it does NOT stop the server — call
/// [`ServerHandle::shutdown_and_join`].
pub struct ServerHandle {
    pub addr: SocketAddr,
    pub metrics: Arc<ServeMetrics>,
    pub catalog: Arc<ZooCatalog>,
    shutdown: Arc<AtomicBool>,
    accept: JoinHandle<()>,
    batcher: JoinHandle<()>,
}

impl ServerHandle {
    /// Stop accepting, drain the batcher, join both threads.
    pub fn shutdown_and_join(self) {
        self.shutdown.store(true, Relaxed);
        let _ = self.accept.join();
        let _ = self.batcher.join();
    }
}

/// Build the zoo catalog: synthetic entries first (ids `synthetic0..N`),
/// then discovered checkpoints. Checkpoints require a runtime to serve;
/// without one they are left out of the catalog (with a notice) so
/// `GET /zoo` never advertises a policy every request against would 500.
fn build_catalog(
    cfg: &ServeConfig, num_actions: usize, have_runtime: bool,
) -> Result<Vec<(String, ZooSource)>> {
    let mut entries: Vec<(String, ZooSource)> = (0..cfg.synthetic_zoo)
        .map(|i| (format!("synthetic{i}"), ZooSource::Synthetic { num_actions }))
        .collect();
    let found = discover_checkpoints(Path::new(&cfg.zoo_dir))
        .with_context(|| format!("scanning zoo dir {:?}", cfg.zoo_dir))?;
    if !have_runtime && !found.is_empty() {
        eprintln!(
            "ued-serve: ignoring {} checkpoint(s) under {:?}: no artifact runtime \
             (start with --artifacts pointing at a compiled artifact set)",
            found.len(),
            cfg.zoo_dir
        );
    } else {
        for (id, path) in found {
            if entries.iter().any(|(e, _)| *e == id) {
                eprintln!("ued-serve: skipping duplicate zoo id {id:?}");
                continue;
            }
            entries.push((id, ZooSource::Checkpoint { path }));
        }
    }
    Ok(entries)
}

/// Start the server: bind, spawn the batcher and accept threads, return
/// immediately. `runtime` is `None` when no compiled artifacts are
/// available (synthetic-only zoo).
pub fn serve<F: EnvFamily>(
    family: F, cfg: ServeConfig, runtime: Option<Runtime>,
) -> Result<ServerHandle> {
    let params = cfg.env_params();
    let env = family.make_env(&params);
    let num_actions = crate::env::UnderspecifiedEnv::num_actions(&env);
    let entries = build_catalog(&cfg, num_actions, runtime.is_some())?;
    anyhow::ensure!(
        !entries.is_empty(),
        "zoo is empty: no checkpoints under {:?} and --synthetic-zoo 0",
        cfg.zoo_dir
    );

    let catalog = Arc::new(ZooCatalog::new(entries));
    let cache = Arc::new(ResultCache::new(cfg.cache_cap));
    let metrics = Arc::new(ServeMetrics::default());
    let queue: Arc<BatchQueue<F::Level>> = Arc::new(BatchQueue::new(cfg.queue_cap));
    let shutdown = Arc::new(AtomicBool::new(false));

    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {:?}", cfg.addr))?;
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let addr = listener.local_addr().context("local addr")?;

    // The batcher owns everything that is Send-but-not-Sync: the runtime
    // (artifact cache is a RefCell) and the engine/policy store.
    let batcher = {
        let queue = queue.clone();
        let cache = cache.clone();
        let metrics = metrics.clone();
        let catalog = catalog.clone();
        let prefix = cfg.env.artifact_prefix();
        let apply_name = cfg.student_apply_artifact();
        let (max_batch, zoo_cap, max_steps) = (cfg.max_batch, cfg.zoo_cap, cfg.max_steps);
        let threads = cfg.rollout_threads.max(1);
        std::thread::Builder::new()
            .name("ued-serve-batcher".to_string())
            .spawn(move || {
                let family = F::default();
                let env = family.make_env(&params);
                let pool = Arc::new(WorkerPool::new(threads));
                let mut engine = RolloutEngine::with_pool(&env, max_batch, pool);
                let mut store = PolicyStore::new(
                    runtime,
                    prefix,
                    apply_name,
                    crate::env::UnderspecifiedEnv::num_actions(&env),
                    zoo_cap,
                    catalog,
                );
                while let Some(works) = queue.drain_blocking() {
                    batcher::run_batches(
                        &env, &mut engine, &mut store, &cache, &metrics, max_steps, works,
                    );
                }
            })
            .context("spawning batcher thread")?
    };

    let ctx = Arc::new(ServeContext::<F> {
        cfg,
        params,
        catalog: catalog.clone(),
        cache,
        metrics: metrics.clone(),
        queue: queue.clone(),
    });

    let accept = {
        let shutdown = shutdown.clone();
        std::thread::Builder::new()
            .name("ued-serve-accept".to_string())
            .spawn(move || {
                // Detached connection threads can outlive the accept loop
                // by a response write; that is fine — they hold only Arcs.
                while !shutdown.load(Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let ctx = ctx.clone();
                            let _ = std::thread::Builder::new()
                                .name("ued-serve-conn".to_string())
                                .spawn(move || handle_connection(stream, &ctx));
                        }
                        // Nonblocking listener: sleep through idle and
                        // transient errors, re-check the shutdown flag.
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
                queue.shutdown();
            })
            .context("spawning accept thread")?
    };

    Ok(ServerHandle { addr, metrics, catalog, shutdown, accept, batcher })
}

/// Serve one request on one connection, then close.
fn handle_connection<F: EnvFamily>(mut stream: TcpStream, ctx: &ServeContext<F>) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    ctx.metrics.requests.fetch_add(1, Relaxed);
    match http::read_request(&mut stream) {
        Ok(req) => {
            let (status, body) = router::handle(ctx, &req);
            let _ = http::write_response(&mut stream, status, &body.to_string());
        }
        Err(http::HttpError::Closed) | Err(http::HttpError::Io(_)) => {}
        Err(e @ http::HttpError::TooLarge(_)) => {
            ctx.metrics.bad_requests.fetch_add(1, Relaxed);
            let body = format!("{{\"error\":{}}}", crate::util::json::Json::from(e.to_string().as_str()).to_string());
            let _ = http::write_response(&mut stream, 413, &body);
        }
        Err(e @ http::HttpError::Malformed(_)) => {
            ctx.metrics.bad_requests.fetch_add(1, Relaxed);
            let body = format!("{{\"error\":{}}}", crate::util::json::Json::from(e.to_string().as_str()).to_string());
            let _ = http::write_response(&mut stream, 400, &body);
        }
    }
}

/// Set when SIGINT/SIGTERM arrives; polled by the binary's main loop.
static SHUTDOWN_SIGNAL: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
type SigHandler = extern "C" fn(i32);

#[cfg(unix)]
extern "C" {
    /// libc `signal(2)`. Used directly because the vendor set has no
    /// `libc`/`signal-hook` crate; returns the previous handler.
    fn signal(signum: i32, handler: SigHandler) -> usize;
}

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    // SAFETY-adjacent: a relaxed atomic store is async-signal-safe.
    SHUTDOWN_SIGNAL.store(true, Relaxed);
}

/// Install SIGINT/SIGTERM handlers that flip [`shutdown_requested`], so
/// the binary can drain and exit 0 instead of being killed mid-batch.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    // SAFETY: `on_signal` only performs a relaxed atomic store, which is
    // async-signal-safe; `signal` is called before any threads handle
    // requests. 2 = SIGINT, 15 = SIGTERM on every Unix we target.
    unsafe {
        signal(2, on_signal);
        signal(15, on_signal);
    }
}

/// Whether a termination signal has been observed.
pub fn shutdown_requested() -> bool {
    SHUTDOWN_SIGNAL.load(Relaxed)
}

/// Serialize servers within one test process: signal state is global and
/// ports are plentiful, but metrics assertions want isolation.
#[cfg(test)]
static TEST_SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::MazeFamily;
    use crate::util::cli::Args;
    use std::io::Read;

    fn serve_cfg(extra: &[&str]) -> ServeConfig {
        let mut argv = vec![
            "--serve-addr".to_string(),
            "127.0.0.1:0".to_string(),
            "--synthetic-zoo".to_string(),
            "2".to_string(),
        ];
        argv.extend(extra.iter().map(|s| s.to_string()));
        ServeConfig::from_args(&Args::parse_from(argv)).unwrap()
    }

    fn get(addr: SocketAddr, path: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        use std::io::Write;
        write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        let status: u16 = raw.split(' ').nth(1).unwrap().parse().unwrap();
        let body = raw.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        (status, body)
    }

    #[test]
    fn startup_healthz_and_clean_shutdown() {
        let _serial = TEST_SERIAL.lock().unwrap();
        let handle = serve(MazeFamily, serve_cfg(&[]), None).unwrap();
        let (status, body) = get(handle.addr, "/healthz");
        assert_eq!((status, body.as_str()), (200, "{\"ok\":true}"));
        let (status, _) = get(handle.addr, "/zoo");
        assert_eq!(status, 200);
        handle.shutdown_and_join();
    }

    #[test]
    fn empty_zoo_refuses_to_start() {
        let _serial = TEST_SERIAL.lock().unwrap();
        let cfg = serve_cfg(&["--synthetic-zoo", "0", "--zoo-dir", "/nonexistent-zoo"]);
        let err = serve(MazeFamily, cfg, None).unwrap_err();
        assert!(err.to_string().contains("zoo is empty"), "{err}");
    }

    #[test]
    fn signal_flag_roundtrip() {
        // Handler installation is idempotent and the flag is observable.
        install_signal_handlers();
        assert!(!shutdown_requested() || SHUTDOWN_SIGNAL.load(Relaxed));
    }
}
