//! Request routing and endpoint handlers.
//!
//! | Endpoint                | Method | Body                                              |
//! |-------------------------|--------|---------------------------------------------------|
//! | `/healthz`              | GET    | —                                                 |
//! | `/zoo`                  | GET    | —                                                 |
//! | `/metrics`              | GET    | —                                                 |
//! | `/eval`                 | POST   | `{"policy", "levels": [hex…], "trials"?, "seed"?}`|
//! | `/levels/generate`      | POST   | `{"seed"?, "mutations"?}`                         |
//!
//! Handlers are pure functions from (shared context, request) to
//! (status, JSON body) — no transport types — so the whole routing layer
//! is unit-testable without sockets.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{mpsc, Arc};

use crate::config::ServeConfig;
use crate::env::{EnvFamily, EnvParams, LevelGenerator, LevelMeta, LevelMutator};
use crate::eval::{EvalReport, LevelResult};
use crate::metrics::ServeMetrics;
use crate::util::json::Json;

use super::batcher::{BatchQueue, EvalWork, PendingLevel};
use super::cache::{cache_key, ResultCache};
use super::http::Request;
use super::zoo::ZooCatalog;

/// Stream id for `/levels/generate` draws (disjoint from training and
/// eval streams; generation for a given seed is fully deterministic).
const GENERATE_STREAM: u64 = 0x5EED;

/// Ceiling on `/levels/generate` mutation counts.
const MAX_MUTATIONS: usize = 10_000;

/// Everything a connection handler needs, shared behind one `Arc`.
pub struct ServeContext<F: EnvFamily> {
    pub cfg: ServeConfig,
    pub params: EnvParams,
    pub catalog: Arc<ZooCatalog>,
    pub cache: Arc<ResultCache>,
    pub metrics: Arc<ServeMetrics>,
    pub queue: Arc<BatchQueue<F::Level>>,
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn err(msg: &str) -> Json {
    obj(vec![("error", Json::from(msg))])
}

/// Route one request. 4xx outcomes bump the bad-request counter here so
/// every transport shares the accounting.
pub fn handle<F: EnvFamily>(ctx: &ServeContext<F>, req: &Request) -> (u16, Json) {
    let (status, body) = route(ctx, req);
    if (400..500).contains(&status) {
        ctx.metrics.bad_requests.fetch_add(1, Relaxed);
    }
    (status, body)
}

fn route<F: EnvFamily>(ctx: &ServeContext<F>, req: &Request) -> (u16, Json) {
    const ENDPOINTS: [&str; 5] = ["/healthz", "/zoo", "/metrics", "/eval", "/levels/generate"];
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, obj(vec![("ok", Json::Bool(true))])),
        ("GET", "/zoo") => zoo(ctx),
        ("GET", "/metrics") => metrics(ctx),
        ("POST", "/eval") => eval(ctx, &req.body),
        ("POST", "/levels/generate") => generate(ctx, &req.body),
        (_, path) if ENDPOINTS.contains(&path) => {
            (405, err(&format!("method {} not allowed on {path}", req.method)))
        }
        (_, path) => (404, err(&format!("no such endpoint: {path}"))),
    }
}

fn zoo<F: EnvFamily>(ctx: &ServeContext<F>) -> (u16, Json) {
    let policies: Vec<Json> = ctx
        .catalog
        .rows()
        .into_iter()
        .map(|(id, loaded, synthetic)| {
            obj(vec![
                ("id", Json::from(id.as_str())),
                ("loaded", Json::Bool(loaded)),
                ("synthetic", Json::Bool(synthetic)),
            ])
        })
        .collect();
    (
        200,
        obj(vec![
            ("policies", Json::Arr(policies)),
            ("capacity", Json::from(ctx.cfg.zoo_cap)),
        ]),
    )
}

fn metrics<F: EnvFamily>(ctx: &ServeContext<F>) -> (u16, Json) {
    let mut pairs: Vec<(&str, Json)> = ctx
        .metrics
        .snapshot()
        .into_iter()
        .map(|(k, v)| (k, Json::Num(v)))
        .collect();
    pairs.push(("zoo_size", Json::from(ctx.catalog.len())));
    pairs.push(("zoo_loaded", Json::from(ctx.catalog.loaded_count())));
    pairs.push(("queue_depth", Json::from(ctx.queue.depth())));
    pairs.push(("cache_entries", Json::from(ctx.cache.len())));
    (200, obj(pairs))
}

fn parse_body(body: &[u8]) -> Result<Json, String> {
    if body.is_empty() {
        return Ok(Json::Obj(BTreeMap::new()));
    }
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    Json::parse(text).map_err(|e| format!("bad json: {e}"))
}

fn eval<F: EnvFamily>(ctx: &ServeContext<F>, body: &[u8]) -> (u16, Json) {
    let j = match parse_body(body) {
        Ok(j) => j,
        Err(e) => return (400, err(&e)),
    };
    let Some(policy) = j.get("policy").and_then(Json::as_str) else {
        return (400, err("missing string field \"policy\""));
    };
    if !ctx.catalog.contains(policy) {
        return (404, err(&format!("unknown policy {policy:?} (see GET /zoo)")));
    }
    let trials = j.get("trials").and_then(Json::as_usize).unwrap_or(ctx.cfg.trials);
    if trials == 0 || trials > ctx.cfg.max_trials {
        return (
            400,
            err(&format!("trials must be in 1..={}", ctx.cfg.max_trials)),
        );
    }
    let master = j.get("seed").and_then(Json::as_f64).map(|x| x as u64).unwrap_or(0);
    let Some(level_hexes) = j.get("levels").and_then(Json::as_arr) else {
        return (400, err("missing array field \"levels\" (hex-encoded level bytes)"));
    };
    if level_hexes.is_empty() {
        return (400, err("\"levels\" must not be empty"));
    }
    if level_hexes.len() > ctx.cfg.max_levels {
        return (
            400,
            err(&format!("at most {} levels per request", ctx.cfg.max_levels)),
        );
    }

    let mut decoded: Vec<(Vec<u8>, F::Level)> = Vec::with_capacity(level_hexes.len());
    for (i, lj) in level_hexes.iter().enumerate() {
        let Some(hex) = lj.as_str() else {
            return (400, err(&format!("level {i}: not a hex string")));
        };
        let bytes = match hex_decode(hex) {
            Ok(b) => b,
            Err(e) => return (400, err(&format!("level {i}: {e}"))),
        };
        let level = match F::Level::decode(&bytes) {
            Ok(l) => l,
            Err(e) => return (400, err(&format!("level {i}: {e}"))),
        };
        if !level.is_valid() {
            return (400, err(&format!("level {i}: decodes but is not a valid level")));
        }
        decoded.push((bytes, level));
    }

    ctx.metrics.eval_requests.fetch_add(1, Relaxed);

    // Cache pass: serve hits immediately, queue only the misses.
    let n = decoded.len();
    let mut resolved: Vec<Option<LevelResult>> = Vec::with_capacity(n);
    let mut misses: Vec<PendingLevel<F::Level>> = Vec::new();
    for (i, (bytes, level)) in decoded.into_iter().enumerate() {
        match ctx.cache.get(&cache_key(policy, trials, master, &bytes)) {
            Some(hit) => {
                ctx.metrics.cache_hits.fetch_add(1, Relaxed);
                resolved.push(Some(hit));
            }
            None => {
                ctx.metrics.cache_misses.fetch_add(1, Relaxed);
                resolved.push(None);
                misses.push(PendingLevel { idx: i, bytes, level });
            }
        }
    }
    let cached_levels = n - misses.len();

    let mut forward_passes = 0u64;
    if !misses.is_empty() {
        let (tx, rx) = mpsc::channel();
        let work = EvalWork {
            policy: policy.to_string(),
            trials,
            master,
            levels: misses,
            respond: tx,
        };
        if !ctx.queue.push(work) {
            ctx.metrics.shed_requests.fetch_add(1, Relaxed);
            return (503, err("eval queue is full, retry later"));
        }
        let outcome = match rx.recv() {
            Ok(o) => o,
            Err(_) => return (500, err("batcher dropped the request")),
        };
        if let Some(e) = outcome.error {
            return (500, err(&e));
        }
        forward_passes = outcome.forward_passes;
        for (idx, r) in outcome.results {
            if idx < resolved.len() {
                // ued-lint: allow(serve-panic) — index guarded by the line above
                resolved[idx] = Some(r);
            }
        }
    }

    let mut levels = Vec::with_capacity(n);
    for slot in resolved {
        match slot {
            Some(r) => levels.push(r),
            None => return (500, err("batcher returned an incomplete result set")),
        }
    }
    let report = EvalReport::from_level_results(levels, forward_passes);
    (
        200,
        obj(vec![
            ("policy", Json::from(policy)),
            ("trials", Json::from(trials)),
            ("seed", Json::Num(master as f64)),
            ("cached_levels", Json::from(cached_levels)),
            ("report", report.to_json()),
        ]),
    )
}

fn generate<F: EnvFamily>(ctx: &ServeContext<F>, body: &[u8]) -> (u16, Json) {
    let j = match parse_body(body) {
        Ok(j) => j,
        Err(e) => return (400, err(&e)),
    };
    let seed = j.get("seed").and_then(Json::as_f64).map(|x| x as u64).unwrap_or(0);
    let mutations = j.get("mutations").and_then(Json::as_usize).unwrap_or(0);
    if mutations > MAX_MUTATIONS {
        return (400, err(&format!("at most {MAX_MUTATIONS} mutations")));
    }
    ctx.metrics.generate_requests.fetch_add(1, Relaxed);

    let family = F::default();
    let mut rng = crate::util::rng::Pcg64::new(seed, GENERATE_STREAM);
    let generator = family.make_generator(&ctx.params);
    let mut level = generator.sample_level(&mut rng);
    if mutations > 0 {
        let mutator = family.make_mutator(&ctx.params);
        for _ in 0..mutations {
            level = mutator.mutate_level(&level, &mut rng);
        }
    }
    (
        200,
        obj(vec![
            ("bytes", Json::from(hex_encode(&level.encode()).as_str())),
            ("valid", Json::Bool(level.is_valid())),
            ("solvable", Json::Bool(level.is_solvable())),
            ("complexity", Json::Num(level.complexity())),
            (
                "fingerprint",
                Json::from(format!("{:016x}", level.fingerprint()).as_str()),
            ),
            ("seed", Json::Num(seed as f64)),
            ("mutations", Json::from(mutations)),
        ]),
    )
}

/// Lowercase hex encoding of level bytes (the wire format for levels).
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Inverse of [`hex_encode`]; accepts upper- or lowercase.
pub fn hex_decode(s: &str) -> Result<Vec<u8>, String> {
    if s.len() % 2 != 0 {
        return Err("hex string has odd length".to_string());
    }
    let digits = s.as_bytes();
    let nibble = |c: u8| -> Result<u8, String> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(format!("invalid hex digit {:?}", c as char)),
        }
    };
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in digits.chunks_exact(2) {
        out.push((nibble(pair[0])? << 4) | nibble(pair[1])?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::holdout::named_levels;
    use crate::env::MazeFamily;
    use crate::util::cli::Args;

    fn ctx() -> ServeContext<MazeFamily> {
        let cfg = ServeConfig::from_args(&Args::parse_from(
            ["--synthetic-zoo", "1", "--queue-cap", "1", "--trials", "2"]
                .iter()
                .map(|s| s.to_string()),
        ))
        .unwrap();
        let params = cfg.env_params();
        ServeContext {
            catalog: Arc::new(ZooCatalog::new(vec![(
                "synthetic0".to_string(),
                super::super::zoo::ZooSource::Synthetic { num_actions: 4 },
            )])),
            cache: Arc::new(ResultCache::new(16)),
            metrics: Arc::new(ServeMetrics::default()),
            queue: Arc::new(BatchQueue::new(cfg.queue_cap)),
            params,
            cfg,
        }
    }

    fn request(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.to_string(),
            path: path.to_string(),
            body: body.as_bytes().to_vec(),
        }
    }

    #[test]
    fn hex_roundtrip_and_rejection() {
        let bytes: Vec<u8> = (0..=255).collect();
        assert_eq!(hex_decode(&hex_encode(&bytes)).unwrap(), bytes);
        assert_eq!(hex_decode("00FFa5").unwrap(), vec![0, 255, 0xA5]);
        assert!(hex_decode("abc").is_err(), "odd length");
        assert!(hex_decode("zz").is_err(), "bad digit");
    }

    #[test]
    fn health_zoo_metrics_and_unknown_routes() {
        let c = ctx();
        let (s, b) = handle(&c, &request("GET", "/healthz", ""));
        assert_eq!((s, b.to_string().as_str()), (200, "{\"ok\":true}"));

        let (s, b) = handle(&c, &request("GET", "/zoo", ""));
        assert_eq!(s, 200);
        let rows = b.get("policies").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("id").unwrap().as_str(), Some("synthetic0"));
        assert_eq!(rows[0].get("loaded").unwrap().as_bool(), Some(false));

        let (s, b) = handle(&c, &request("GET", "/metrics", ""));
        assert_eq!(s, 200);
        assert_eq!(b.get("forward_passes").unwrap().as_f64(), Some(0.0));
        assert_eq!(b.get("zoo_size").unwrap().as_usize(), Some(1));

        let (s, _) = handle(&c, &request("GET", "/nope", ""));
        assert_eq!(s, 404);
        let (s, _) = handle(&c, &request("DELETE", "/eval", ""));
        assert_eq!(s, 405);
        // the 404 and 405 above were counted
        assert_eq!(c.metrics.bad_requests.load(Relaxed), 2);
    }

    #[test]
    fn eval_validation_rejects_before_queueing() {
        let c = ctx();
        let level_hex = hex_encode(&named_levels()[0].level.encode());
        let cases: &[(&str, u16)] = &[
            ("not json", 400),
            ("{}", 400),
            (r#"{"policy":"ghost","levels":["00"]}"#, 404),
            (r#"{"policy":"synthetic0"}"#, 400),
            (r#"{"policy":"synthetic0","levels":[]}"#, 400),
            (r#"{"policy":"synthetic0","levels":["zz"]}"#, 400),
            (r#"{"policy":"synthetic0","levels":["0011"]}"#, 400),
            (r#"{"policy":"synthetic0","levels":[7]}"#, 400),
        ];
        for (body, want) in cases {
            let (s, b) = handle(&c, &request("POST", "/eval", body));
            assert_eq!(s, *want, "{body} → {}", b.to_string());
        }
        // over-cap trials rejected even with a fine level
        let body = format!(
            r#"{{"policy":"synthetic0","levels":["{level_hex}"],"trials":1000}}"#
        );
        let (s, _) = handle(&c, &request("POST", "/eval", &body));
        assert_eq!(s, 400);
        // nothing ever reached the queue
        assert_eq!(c.queue.depth(), 0);
        assert_eq!(
            c.metrics.eval_requests.load(Relaxed),
            0,
            "every request was rejected before admission"
        );
    }

    #[test]
    fn eval_sheds_with_503_when_the_queue_is_full() {
        let c = ctx(); // queue cap 1
        // stuff the queue so the next push fails
        let (tx, _rx) = mpsc::channel();
        assert!(c.queue.push(EvalWork {
            policy: "synthetic0".to_string(),
            trials: 1,
            master: 0,
            levels: Vec::new(),
            respond: tx,
        }));
        let level_hex = hex_encode(&named_levels()[0].level.encode());
        let body =
            format!(r#"{{"policy":"synthetic0","levels":["{level_hex}"]}}"#);
        let (s, _) = handle(&c, &request("POST", "/eval", &body));
        assert_eq!(s, 503);
        assert_eq!(c.metrics.shed_requests.load(Relaxed), 1);
    }

    #[test]
    fn generate_is_deterministic_per_seed() {
        let c = ctx();
        let body = r#"{"seed": 42, "mutations": 3}"#;
        let (s1, b1) = handle(&c, &request("POST", "/levels/generate", body));
        let (s2, b2) = handle(&c, &request("POST", "/levels/generate", body));
        assert_eq!((s1, s2), (200, 200));
        assert_eq!(b1.to_string(), b2.to_string(), "same seed → same level");
        let (s3, b3) =
            handle(&c, &request("POST", "/levels/generate", r#"{"seed": 43}"#));
        assert_eq!(s3, 200);
        assert_ne!(
            b1.get("bytes").unwrap().as_str(),
            b3.get("bytes").unwrap().as_str(),
            "different seed → different level"
        );
        // generated bytes round-trip through the eval decode path
        let hex = b1.get("bytes").unwrap().as_str().unwrap();
        let decoded =
            <MazeFamily as EnvFamily>::Level::decode(&hex_decode(hex).unwrap()).unwrap();
        assert!(decoded.is_valid());
        // an empty body uses defaults
        let (s, b) = handle(&c, &request("POST", "/levels/generate", ""));
        assert_eq!(s, 200);
        assert_eq!(b.get("seed").unwrap().as_f64(), Some(0.0));
        // mutation cap enforced
        let (s, _) = handle(
            &c,
            &request("POST", "/levels/generate", r#"{"mutations": 99999}"#),
        );
        assert_eq!(s, 400);
    }
}
