//! Minimal HTTP/1.1 framing for `ued-serve` — request parsing and
//! response writing over any `Read`/`Write`, no TCP assumptions (tests
//! drive it with in-memory cursors).
//!
//! Deliberately small: one request per connection (`Connection: close`),
//! no chunked transfer, no keep-alive, header section capped at
//! [`MAX_HEAD_BYTES`] and bodies at [`MAX_BODY_BYTES`] so a hostile peer
//! cannot balloon memory before the JSON layer's own guards
//! (`MAX_PARSE_BYTES`) even see the payload.

use std::io::{Read, Write};

/// Cap on the request line + headers.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on request bodies (well under the JSON parser's own input cap).
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed request: method, path (query strings are not split off —
/// the router matches exact targets), raw body bytes.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Why a request could not be read.
#[derive(Debug)]
pub enum HttpError {
    /// Peer closed before a full request arrived.
    Closed,
    /// Head or body exceeded its cap (maps to 413).
    TooLarge(&'static str),
    /// Unparseable framing (maps to 400).
    Malformed(String),
    /// Transport error (connection is dropped without a response).
    Io(std::io::Error),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Closed => write!(f, "connection closed mid-request"),
            HttpError::TooLarge(what) => write!(f, "{what} too large"),
            HttpError::Malformed(m) => write!(f, "malformed request: {m}"),
            HttpError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl From<std::io::Error> for HttpError {
    fn from(e: std::io::Error) -> HttpError {
        HttpError::Io(e)
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
}

/// Read and parse one request. Blocks until the head (and, when a
/// `Content-Length` is present, the full body) has arrived; the caller
/// is expected to have armed a read timeout on the transport.
pub fn read_request<R: Read>(r: &mut R) -> Result<Request, HttpError> {
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut chunk = [0u8; 2048];
    let head_end = loop {
        if let Some(end) = find_head_end(&buf) {
            break end;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(HttpError::TooLarge("request head"));
        }
        let n = r.read(&mut chunk)?;
        if n == 0 {
            if buf.is_empty() {
                return Err(HttpError::Closed);
            }
            return Err(HttpError::Malformed("eof before end of headers".into()));
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| HttpError::Malformed("non-utf8 head".into()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| HttpError::Malformed("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| HttpError::Malformed("request line has no target".into()))?
        .to_string();
    match parts.next() {
        Some(v) if v.starts_with("HTTP/1.") => {}
        _ => return Err(HttpError::Malformed("not an HTTP/1.x request".into())),
    }

    let mut content_length = 0usize;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        if name.trim().eq_ignore_ascii_case("content-length") {
            content_length = value
                .trim()
                .parse()
                .map_err(|_| HttpError::Malformed("bad content-length".into()))?;
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(HttpError::TooLarge("request body"));
    }

    let mut body: Vec<u8> = buf[head_end..].to_vec();
    if body.len() > content_length {
        return Err(HttpError::Malformed("body longer than content-length".into()));
    }
    while body.len() < content_length {
        let n = r.read(&mut chunk)?;
        if n == 0 {
            return Err(HttpError::Malformed("eof before end of body".into()));
        }
        body.extend_from_slice(&chunk[..n]);
        if body.len() > content_length {
            return Err(HttpError::Malformed("body longer than content-length".into()));
        }
    }

    Ok(Request { method, path, body })
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one JSON response and flush. Always `Connection: close` — the
/// server's unit of work is one request.
pub fn write_response<W: Write>(w: &mut W, status: u16, body: &str) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        status,
        reason(status),
        body.len(),
        body
    )?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(raw: &str) -> Result<Request, HttpError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_get_and_post() {
        let r = req("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert!(r.body.is_empty());

        let r = req(
            "POST /eval HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: 7\r\n\r\n{\"a\":1}",
        )
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, b"{\"a\":1}");
    }

    #[test]
    fn content_length_is_case_insensitive() {
        let r = req("POST /x HTTP/1.1\r\ncOnTeNt-LeNgTh: 2\r\n\r\nhi").unwrap();
        assert_eq!(r.body, b"hi");
    }

    #[test]
    fn rejects_malformed_framing() {
        assert!(matches!(req(""), Err(HttpError::Closed)));
        assert!(matches!(req("GET /x\r\n\r\n"), Err(HttpError::Malformed(_))));
        assert!(matches!(req("GARBAGE\r\n\r\n"), Err(HttpError::Malformed(_))));
        assert!(matches!(
            req("POST /x HTTP/1.1\r\nContent-Length: zzz\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        // truncated body: peer closed before content-length bytes arrived
        assert!(matches!(
            req("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nhi"),
            Err(HttpError::Malformed(_))
        ));
    }

    #[test]
    fn enforces_size_caps() {
        let huge_head = format!("GET /x HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES));
        assert!(matches!(req(&huge_head), Err(HttpError::TooLarge(_))));
        let huge_body =
            format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(matches!(req(&huge_body), Err(HttpError::TooLarge(_))));
    }

    #[test]
    fn response_framing() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "{\"ok\":true}").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 11\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.ends_with("{\"ok\":true}"), "{text}");
        let mut out = Vec::new();
        write_response(&mut out, 503, "{}").unwrap();
        assert!(String::from_utf8(out).unwrap().starts_with("HTTP/1.1 503 Service Unavailable"));
    }
}
