//! Splittable PCG-64 pseudo-random number generator.
//!
//! No `rand` crate is available in the offline vendor set, so the library
//! carries its own PRNG. PCG-XSL-RR-128/64 (O'Neill 2014): a 128-bit LCG
//! state with an output permutation — fast, statistically strong for
//! simulation workloads, and trivially seedable/splittable, which the UED
//! drivers use to give every subsystem (level generation, action sampling,
//! meta-policy, mutations) an independent stream.

/// PCG-XSL-RR-128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed and a stream id. Distinct
    /// stream ids yield statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience: seed with stream 0.
    pub fn seed_from_u64(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (used to hand one stream per
    /// subsystem without correlating their draws).
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::new(seed, stream)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        // XSL-RR output permutation.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 high bits -> mantissa-exact uniform.
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Unbiased uniform integer in [0, n) (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (used only off the hot path).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "all-zero weight vector");
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(1, 2);
        let mut b = Pcg64::new(1, 2);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1, 0);
        let mut b = Pcg64::new(2, 0);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg64::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f32_mean_near_half() {
        let mut r = Pcg64::seed_from_u64(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg64::seed_from_u64(5);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_range_unbiased_small() {
        // chi-square-ish sanity for n=3
        let mut r = Pcg64::seed_from_u64(9);
        let mut counts = [0usize; 3];
        let n = 90_000;
        for _ in 0..n {
            counts[r.gen_range(3)] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 3.0;
            assert!((c as f64 - expect).abs() < expect * 0.05, "{counts:?}");
        }
    }

    #[test]
    fn weighted_sampling_proportions() {
        let mut r = Pcg64::seed_from_u64(13);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert!((counts[2] as f64 / n as f64 - 0.6).abs() < 0.02, "{counts:?}");
        assert!((counts[1] as f64 / n as f64 - 0.3).abs() < 0.02, "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seed_from_u64(19);
        for _ in 0..100 {
            let idx = r.sample_indices(20, 8);
            assert_eq!(idx.len(), 8);
            let mut s = idx.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
        }
    }

    #[test]
    fn split_independent() {
        let mut root = Pcg64::seed_from_u64(23);
        let mut a = root.split();
        let mut b = root.split();
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed_from_u64(29);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
