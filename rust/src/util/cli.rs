//! Tiny command-line flag parser (no `clap` in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; produces self-describing usage errors. Used by the `jaxued`
//! launcher and the example/bench binaries.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags the program looked up — for unknown-flag detection.
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an explicit list (testable); `std::env::args` for real use.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Flags that were provided but never queried (probable typos).
    pub fn unknown_flags(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.flags
            .keys()
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("--seed 7 --algo=plr train");
        assert_eq!(a.get_usize("seed", 0), 7);
        assert_eq!(a.get_str("algo", ""), "plr");
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("--verbose --n 3");
        assert!(a.get_bool("verbose", false));
        assert_eq!(a.get_usize("n", 0), 3);
        assert!(!a.get_bool("quiet", false));
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("--x 1 --flag");
        assert!(a.get_bool("flag", false));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_f64("lr", 1e-4), 1e-4);
        assert_eq!(a.get_str("algo", "dr"), "dr");
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("--good 1 --oops 2");
        let _ = a.get_usize("good", 0);
        assert_eq!(a.unknown_flags(), vec!["oops".to_string()]);
    }

    #[test]
    fn negative_number_values() {
        let a = parse("--x=-3.5");
        assert_eq!(a.get_f64("x", 0.0), -3.5);
    }
}
