//! Minimal JSON parser and writer.
//!
//! `serde` is not in the offline vendor set, so the library carries a small
//! recursive-descent JSON implementation. It is used off the hot path only:
//! reading `artifacts/manifest.json`, training configs, and writing metric
//! summaries — and, since the `ued-serve` layer, parsing request bodies
//! that arrive off the network. Supports the full JSON grammar except `\u`
//! surrogate pairs beyond the BMP (not needed by any of our producers).
//!
//! Untrusted-input guards: inputs larger than [`MAX_PARSE_BYTES`] and
//! nesting deeper than [`MAX_PARSE_DEPTH`] are parse errors, never stack
//! overflows (the parser is recursive-descent, so unbounded `[[[[…` would
//! otherwise recurse once per bracket).

use std::collections::BTreeMap;
use std::fmt;

/// Maximum input size `Json::parse` accepts. Generous — real manifests are
/// a few hundred KB and HTTP bodies are capped far below this — but finite,
/// so a hostile payload can't commit us to unbounded tree allocation.
pub const MAX_PARSE_BYTES: usize = 16 * 1024 * 1024;

/// Maximum container nesting depth. Every legitimate producer in this repo
/// nests < 10 deep; 128 leaves headroom while keeping worst-case parser
/// recursion far inside the default thread stack.
pub const MAX_PARSE_DEPTH: usize = 128;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        if s.len() > MAX_PARSE_BYTES {
            return Err(JsonError {
                msg: format!("input of {} bytes exceeds MAX_PARSE_BYTES", s.len()),
                pos: 0,
            });
        }
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key — manifest
    /// parsing uses this so failures are self-describing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    /// Bump the container depth on `[`/`{`; errors abort the whole parse so
    /// only the `Ok` paths of `array`/`object` unwind it.
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_PARSE_DEPTH {
            return Err(self.err("nesting exceeds MAX_PARSE_DEPTH"));
        }
        Ok(())
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        // ued-lint: allow(serve-panic) — the scanned range is all ASCII digit/sign/dot bytes, so from_utf8 cannot fail
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.enter()?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"n":-3}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn deep_nesting_is_an_error_not_an_overflow() {
        // Far deeper than any stack could take via naive recursion: the
        // depth guard must kick in after MAX_PARSE_DEPTH containers.
        let deep = "[".repeat(200_000);
        assert!(Json::parse(&deep).is_err());
        let deep_obj = r#"{"a":"#.repeat(200_000);
        assert!(Json::parse(&deep_obj).is_err());
        // Mixed nesting just past the limit also errors...
        let over = format!(
            "{}1{}",
            "[".repeat(MAX_PARSE_DEPTH + 1),
            "]".repeat(MAX_PARSE_DEPTH + 1)
        );
        assert!(Json::parse(&over).is_err());
        // ...while nesting at the limit still parses.
        let at = format!("{}1{}", "[".repeat(MAX_PARSE_DEPTH), "]".repeat(MAX_PARSE_DEPTH));
        assert!(Json::parse(&at).is_ok());
    }

    #[test]
    fn depth_is_per_branch_not_cumulative() {
        // Thousands of sibling containers at shallow depth must stay fine:
        // the guard tracks nesting, not total container count.
        let wide = format!("[{}{{}}]", "{},".repeat(5_000));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn oversized_input_is_rejected_up_front() {
        let big = " ".repeat(MAX_PARSE_BYTES + 1);
        let err = Json::parse(&big).unwrap_err();
        assert!(err.msg.contains("MAX_PARSE_BYTES"), "{err}");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":1,"artifacts":[{"name":"x","inputs":[{"shape":[2,3],"dtype":"float32"}]}]}"#;
        let j = Json::parse(src).unwrap();
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![2, 3]);
    }
}
