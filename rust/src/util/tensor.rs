//! Row-major host tensors.
//!
//! The rollout engine assembles observation/trajectory arrays on the host
//! before staging them into PJRT literals; this module is the thin,
//! allocation-conscious container it uses. Only the dtypes the artifact ABI
//! needs exist (f32, i32).

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl TensorF32 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        TensorF32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(TensorF32 { shape: shape.to_vec(), data })
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Zero all elements without reallocating (hot-loop reuse).
    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Flat offset of a multi-index (debug-checked).
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < d, "index {x} out of bounds for dim {i} ({d})");
            off = off * d + x;
        }
        off
    }

    #[inline]
    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// Mutable view of the contiguous slice at leading index `i`
    /// (e.g. row `t` of a `[T, B, ...]` buffer).
    pub fn slice_mut(&mut self, i: usize) -> &mut [f32] {
        let stride: usize = self.shape[1..].iter().product();
        &mut self.data[i * stride..(i + 1) * stride]
    }

    pub fn slice(&self, i: usize) -> &[f32] {
        let stride: usize = self.shape[1..].iter().product();
        &self.data[i * stride..(i + 1) * stride]
    }

    /// Convert to an xla literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Convert to a literal with an explicit shape (same element count) —
    /// used to stage flat observation buffers as the artifact's structured
    /// input shapes, e.g. `[B, 75]` data as a `[B, 5, 5, 3]` literal.
    pub fn to_literal_as(&self, dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        if n != self.data.len() {
            bail!("cannot view {:?} as {:?}", self.shape, dims);
        }
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

/// Dense row-major i32 tensor (actions).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl TensorI32 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        TensorI32 { shape: shape.to_vec(), data: vec![0; n] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn data(&self) -> &[i32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }

    pub fn slice_mut(&mut self, i: usize) -> &mut [i32] {
        let stride: usize = self.shape[1..].iter().product();
        &mut self.data[i * stride..(i + 1) * stride]
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_row_major() {
        let t = TensorF32::zeros(&[2, 3, 4]);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
        assert_eq!(t.offset(&[0, 0, 3]), 3);
        assert_eq!(t.offset(&[0, 1, 0]), 4);
        assert_eq!(t.offset(&[1, 0, 0]), 12);
        assert_eq!(t.offset(&[1, 2, 3]), 23);
    }

    #[test]
    fn get_set() {
        let mut t = TensorF32::zeros(&[3, 3]);
        t.set(&[1, 2], 5.0);
        assert_eq!(t.get(&[1, 2]), 5.0);
        assert_eq!(t.data().iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn slice_mut_strides() {
        let mut t = TensorF32::zeros(&[4, 2, 2]);
        t.slice_mut(2).fill(7.0);
        assert_eq!(t.get(&[2, 1, 1]), 7.0);
        assert_eq!(t.get(&[1, 1, 1]), 0.0);
        assert_eq!(t.get(&[3, 0, 0]), 0.0);
    }

    #[test]
    fn from_vec_validates() {
        assert!(TensorF32::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(TensorF32::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn fill_resets() {
        let mut t = TensorF32::zeros(&[8]);
        t.set(&[3], 1.0);
        t.fill(0.0);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }
}
