//! Miniature property-based testing harness.
//!
//! The offline vendor set has no `proptest`/`quickcheck`, so the library
//! carries its own: seeded case generation from `Pcg64`, a configurable
//! case count, and greedy shrinking for the built-in generators. Property
//! tests across the crate (level sampler invariants, env round-trips, maze
//! generation, meta-policy frequencies) are written against this module.
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the xla rpath link flag
//! use jaxued::prop_assert;
//! use jaxued::util::proptest::props;
//! props(100, |g| {
//!     let n = g.usize_in(1, 50);
//!     let mut v = g.vec_f64(n, -1.0, 1.0);
//!     v.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     prop_assert!(v.windows(2).all(|w| w[0] <= w[1]), "sorted");
//!     Ok(())
//! });
//! ```

use crate::util::rng::Pcg64;

/// Per-case random value source. Records draws so failures replay exactly.
pub struct Gen {
    rng: Pcg64,
    /// Human-readable log of draws for failure reports.
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64, case: u64) -> Self {
        Gen { rng: Pcg64::new(seed, case), log: Vec::new() }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.gen_range(hi - lo + 1);
        self.log.push(format!("usize[{lo},{hi}]={v}"));
        v
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.log.push(format!("u64={v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.rng.next_f64() * (hi - lo);
        self.log.push(format!("f64[{lo},{hi}]={v:.6}"));
        v
    }

    pub fn bool(&mut self, p: f64) -> bool {
        let v = self.rng.gen_bool(p);
        self.log.push(format!("bool({p})={v}"));
        v
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| lo + self.rng.next_f64() * (hi - lo)).collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| lo + self.rng.gen_range(hi - lo + 1)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_range(xs.len())]
    }

    /// Direct access for compound structures.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Property outcome: Err carries the failure description.
pub type PropResult = Result<(), String>;

/// Assert inside a property, carrying a message instead of panicking.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert equality with a diagnostic.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{:?} != {:?}", a, b));
        }
    }};
}

/// Run `cases` random cases of the property. Panics with the seed and the
/// generator's draw log on the first failure, so the case can be replayed
/// by fixing `JAXUED_PROP_SEED`.
pub fn props(cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    let seed = std::env::var("JAXUED_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(UED_SEED_DEFAULT);
    for case in 0..cases {
        let mut g = Gen::new(seed, case);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed (seed={seed}, case={case}): {msg}\n  draws: {}",
                g.log.join(", ")
            );
        }
    }
}

const UED_SEED_DEFAULT: u64 = 0x1a2b_3c4d_5e6f_7788;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn props_pass() {
        props(50, |g| {
            let a = g.usize_in(0, 10);
            let b = g.usize_in(0, 10);
            prop_assert!(a + b <= 20, "sum bounded");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn props_fail_panics_with_seed() {
        props(50, |g| {
            let a = g.usize_in(0, 10);
            prop_assert!(a < 5, "a={a} not < 5");
            Ok(())
        });
    }

    #[test]
    fn gen_ranges_inclusive() {
        props(200, |g| {
            let x = g.usize_in(3, 5);
            prop_assert!((3..=5).contains(&x), "x={x}");
            let f = g.f64_in(-1.0, 1.0);
            prop_assert!((-1.0..1.0).contains(&f), "f={f}");
            Ok(())
        });
    }
}
