//! Self-contained substrate utilities (the offline vendor set has no rand /
//! serde / clap / proptest, so the library carries its own).
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod tensor;
