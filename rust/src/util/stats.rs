//! Aggregation statistics for evaluation (Figure 3 / Table 2).
//!
//! The paper reports the IQM (inter-quartile mean) of mean solve rates with
//! min–max error bars over seeds, and mean ± std for Table 2. Implemented
//! here from scratch (no external stats crate), plus a bootstrap CI helper
//! for robustness analyses.

use crate::util::rng::Pcg64;

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Standard error of the mean (sample standard deviation / √n, the
/// Figure-3 error-bar quantity for cross-seed aggregates); 0 for n < 2.
pub fn std_err(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
    (var / n as f64).sqrt()
}

/// Linear-interpolation quantile (numpy default), q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = pos - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

/// Inter-quartile mean: the mean of the middle 50% of the data (rliable's
/// IQM, the aggregation used in Figure 3). Uses the trimmed-mean definition:
/// drop the bottom and top 25% of *samples* (fractional trimming at the
/// boundaries).
pub fn iqm(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    // ued-lint: allow(serve-panic) — inputs are episode returns, finite by construction (no NaN source in the reward path)
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len() as f64;
    let trim = n * 0.25;
    // Each sorted sample i occupies the unit interval [i, i+1); its IQM
    // weight is that interval's overlap with the kept band [trim, n-trim].
    let mut total = 0.0;
    let mut weight = 0.0;
    for (i, &x) in s.iter().enumerate() {
        let lo = (i as f64).max(trim);
        let hi = ((i + 1) as f64).min(n - trim);
        let w = (hi - lo).max(0.0);
        total += x * w;
        weight += w;
    }
    if weight == 0.0 {
        mean(&s)
    } else {
        total / weight
    }
}

/// Min and max of a slice.
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// Percentile bootstrap confidence interval for a statistic.
pub fn bootstrap_ci(
    xs: &[f64], stat: impl Fn(&[f64]) -> f64, n_resamples: usize, alpha: f64,
    rng: &mut Pcg64,
) -> (f64, f64) {
    assert!(!xs.is_empty());
    let mut stats = Vec::with_capacity(n_resamples);
    let mut buf = vec![0.0; xs.len()];
    for _ in 0..n_resamples {
        for b in buf.iter_mut() {
            *b = xs[rng.gen_range(xs.len())];
        }
        stats.push(stat(&buf));
    }
    (quantile(&stats, alpha / 2.0), quantile(&stats, 1.0 - alpha / 2.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn std_err_basics() {
        assert_eq!(std_err(&[]), 0.0);
        assert_eq!(std_err(&[1.0]), 0.0);
        assert_eq!(std_err(&[2.0, 2.0, 2.0]), 0.0);
        // [1,2,3,4]: sample var 5/3, stderr sqrt(5/3)/2
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((std_err(&xs) - (5.0f64 / 3.0).sqrt() / 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        assert!((quantile(&xs, 0.5) - 1.5).abs() < 1e-12);
        assert!((quantile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn iqm_drops_tails() {
        // 8 values: trim 2 from each side exactly.
        let xs = [-100.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 100.0];
        assert!((iqm(&xs) - 2.5).abs() < 1e-9, "{}", iqm(&xs));
    }

    #[test]
    fn iqm_robust_to_outlier() {
        let clean = [0.4, 0.5, 0.5, 0.6, 0.5, 0.55, 0.45, 0.5];
        let mut dirty = clean;
        dirty[0] = -10.0;
        assert!((iqm(&clean) - iqm(&dirty)).abs() < 0.06);
    }

    #[test]
    fn iqm_singleton() {
        assert!((iqm(&[3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn iqm_uniform_data_is_mean() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert!((iqm(&xs) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn minmax() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
    }

    #[test]
    fn bootstrap_contains_truth() {
        let mut rng = Pcg64::seed_from_u64(1);
        let xs: Vec<f64> = (0..200).map(|_| rng.next_f64()).collect();
        let (lo, hi) = bootstrap_ci(&xs, mean, 500, 0.05, &mut rng);
        assert!(lo < 0.5 && 0.5 < hi, "({lo},{hi})");
        assert!(hi - lo < 0.2);
    }
}
