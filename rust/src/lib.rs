//! # JaxUED-rs
//!
//! A Rust + JAX + Pallas reproduction of **JaxUED** (Coward, Beukman,
//! Foerster 2024): Unsupervised Environment Design algorithms — DR, PLR,
//! robust PLR (PLR⊥), ACCEL, and PAIRED — as a Rust coordinator driving
//! AOT-compiled XLA compute artifacts. Python/JAX runs only at build time
//! (`make artifacts`); the training hot path is pure Rust + PJRT.
//!
//! Layering (DESIGN.md):
//! * [`env`] — the `UnderspecifiedEnv` interface plus the level-lifecycle
//!   capability traits (`LevelGenerator`/`LevelMutator`/`LevelMeta`), the
//!   `EnvFamily` registry (`--env maze|lava`), the maze + lava + editor
//!   envs, wrappers, rendering, holdout suites, and the reusable
//!   conformance property suite.
//! * [`level_sampler`] — the prioritized rolling level buffer.
//! * [`runtime`] — PJRT client, artifact manifest (env-scoped artifact
//!   name resolution), parameter store.
//! * [`rollout`] — pipelined B-way rollout engine (persistent worker
//!   pool, per-column RNG streams, work-queue episode runner) +
//!   trajectory storage.
//! * [`ppo`] — the train-step driver (the update itself is an AOT artifact).
//! * [`algo`] — DR / PLR / PLR⊥ / ACCEL / PAIRED drivers + training loop,
//!   generic over the env family.
//! * [`analysis`] — `ued-lint`, the in-tree determinism/unsafety
//!   static-analysis pass (run by the `ued_lint` binary and CI).
//! * [`serve`] — `ued-serve`, the batched policy-zoo evaluation server
//!   (dependency-free HTTP/1.1 + JSON; micro-batches concurrent `/eval`
//!   requests into the work-queue rollout engine).
//! * [`eval`], [`metrics`], [`config`], [`util`] — support systems.

// Enforced by `ued-lint` (rule `unsafe-op-lint`): every unsafe operation
// must sit in an explicit `unsafe` block — each carrying its own SAFETY
// comment — even inside `unsafe fn` bodies.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod algo;
pub mod analysis;
pub mod config;
pub mod env;
pub mod eval;
pub mod level_sampler;
pub mod metrics;
pub mod ppo;
pub mod rollout;
pub mod runtime;
pub mod serve;
pub mod util;
